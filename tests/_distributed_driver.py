"""Subprocess driver for multi-device tests (8 host devices).

Run directly: ``PYTHONPATH=src python tests/_distributed_driver.py``.
Invoked by test_distributed.py in a fresh process because the XLA host
device count must be set before jax initializes.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.compat import AxisType, make_mesh  # noqa: E402
from repro.core.relation import Database, full_reduce  # noqa: E402
from repro.core.join_tree import JoinTree, build_plan  # noqa: E402
from repro.core.materialize import materialize_join  # noqa: E402
from repro.core.figaro import figaro_r0  # noqa: E402
from repro.core.postprocess import normalize_sign  # noqa: E402
from repro.core.distributed import (distributed_postprocess_r0,  # noqa: E402
                                    distributed_qr_r, partitioned_figaro_qr)


def main() -> None:
    assert len(jax.devices()) == 8, jax.devices()
    rng = np.random.default_rng(2)
    mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))

    tables = {
        "F": ({"a": rng.integers(0, 8, 60), "b": rng.integers(0, 5, 60)},
              rng.normal(size=(60, 3)), ["f0", "f1", "f2"]),
        "D1": ({"a": rng.integers(0, 8, 25)}, rng.normal(size=(25, 2)),
               ["d0", "d1"]),
        "D2": ({"b": rng.integers(0, 5, 18)}, rng.normal(size=(18, 2)),
               ["e0", "e1"]),
    }
    db = Database.from_arrays(tables)
    edges = [("F", "D1"), ("F", "D2")]
    db = full_reduce(db, edges)
    tree = JoinTree.from_edges(db, "F", edges)
    plan = build_plan(tree)
    a = materialize_join(tree)
    r_ref = np.asarray(normalize_sign(jnp.linalg.qr(jnp.array(a), mode="r")))

    # 1) mesh-distributed THIN/TSQR post-processing of R0
    r0 = figaro_r0(plan, dtype=jnp.float64)
    r_dist = np.asarray(distributed_postprocess_r0(r0, mesh, "data"))
    err = np.abs(r_dist - r_ref).max() / np.abs(r_ref).max()
    assert err < 1e-10, ("distributed_postprocess_r0", err)

    # 2) domain-parallel FiGaRo: fact table partitioned across workers
    r_part = np.asarray(partitioned_figaro_qr(tree, 4))
    err2 = np.abs(r_part - r_ref).max() / np.abs(r_ref).max()
    assert err2 < 1e-10, ("partitioned_figaro_qr", err2)

    # 3) distributed dense QR (TSQR over the mesh) on a tall matrix
    x = jnp.array(rng.normal(size=(512, 12)))
    r3 = np.asarray(normalize_sign(distributed_qr_r(x, mesh, "data")))
    r3_ref = np.asarray(normalize_sign(jnp.linalg.qr(x, mode="r")))
    assert np.abs(r3 - r3_ref).max() < 1e-10 * np.abs(r3_ref).max()

    print("DISTRIBUTED-OK")


if __name__ == "__main__":
    main()
