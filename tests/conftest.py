"""Shared test config.

float64 is enabled so the core-math oracles are tight (the paper evaluates in
double precision); model tests cast explicitly via cfg dtypes and are
unaffected. The XLA device-count flag is NEVER set here — distributed tests
spawn subprocesses (see test_distributed.py / test_dryrun.py) so smoke tests
and benchmarks keep seeing the single real device.

When the real ``hypothesis`` package is absent (the container doesn't ship
it), a minimal deterministic stand-in is installed into ``sys.modules`` before
test modules import: ``@given`` runs each property test over ``max_examples``
pseudo-random draws from a fixed seed. Same API subset, reproducible draws,
no external dependency.
"""

import functools
import inspect
import sys
import types

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 20)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **{**kwargs, **draws})
            # pytest must not see the strategy-bound params (it would resolve
            # them as fixtures) nor unwrap back to the original signature.
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._stub_max_examples = max_examples
            return fn
        return deco

    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.booleans = booleans
    strat.sampled_from = sampled_from
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat


_install_hypothesis_stub()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
