"""Shared test config.

float64 is enabled so the core-math oracles are tight (the paper evaluates in
double precision); model tests cast explicitly via cfg dtypes and are
unaffected. The XLA device-count flag is NEVER set here — distributed tests
spawn subprocesses (see test_distributed.py / test_dryrun.py) so smoke tests
and benchmarks keep seeing the single real device.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
