"""figaro-san runtime sanitizer: enable/disable semantics and near-zero
disabled cost, lock-order cycle detection on a synthetic deadlock fixture,
lockset race detection (fires on the unlocked fixture, quiet on the fixed
one and on the instrumented production classes), retrace attribution naming
the diverged signature component, and the float64 shadow dispatch asserting
the paper's database-size error budget on the retailer/yelp schemas."""

import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sanitizer
from repro.core.engine import FigaroEngine
from repro.core.join_tree import build_plan
from repro.data.relational import retailer_like, yelp_like
from repro.sanitizer import numerics as san_numerics
from repro.sanitizer import retrace as san_retrace
from repro.sanitizer.locks import san_lock, san_rlock
from repro.sanitizer.races import shared_state
from repro.sanitizer.threads import san_thread


@pytest.fixture
def san():
    """Sanitizer armed for one test, fully torn down after."""
    sanitizer.enable(sample_every=1)
    sanitizer.reset()
    yield sanitizer
    sanitizer.reset()
    sanitizer.disable()


def _run_threads(*targets):
    threads = [threading.Thread(target=t) for t in targets]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)


# -- enable / disable ---------------------------------------------------------


def test_disabled_by_default_and_hooks_physically_removed():
    assert not sanitizer.enabled()
    # Disabled means the race hooks are *gone* from the instrumented classes,
    # not short-circuiting: the hot path pays nothing.
    from repro.core.plan_cache import PlanHolder

    assert "__getattribute__" not in PlanHolder.__dict__
    sanitizer.enable()
    try:
        assert sanitizer.enabled()
        assert "__getattribute__" in PlanHolder.__dict__
    finally:
        sanitizer.disable()
    assert "__getattribute__" not in PlanHolder.__dict__


def test_report_empty_and_grouped(san):
    assert "no findings" in san.report()
    sanitizer.STATE.add_finding("race", "synthetic", details={})
    assert "race" in san.report() and "synthetic" in san.report()


# -- lock-order cycles --------------------------------------------------------


def test_lock_order_cycle_flagged_on_synthetic_deadlock(san):
    """Classic AB/BA inversion: each acquisition order is individually fine,
    together they can deadlock. The graph flags the cycle without needing the
    interleaving to actually hang."""
    a, b = san_lock("fixture.A"), san_lock("fixture.B")
    with a:
        with b:
            pass
    assert san.findings("lock-order") == []
    with b:
        with a:
            pass
    msgs = [f.message for f in san.findings("lock-order")]
    assert any("lock acquisition cycle (potential deadlock)" in m
               and "fixture.A" in m and "fixture.B" in m for m in msgs)


def test_consistent_lock_order_is_quiet(san):
    a, b = san_lock("fixture.C"), san_lock("fixture.D")
    for _ in range(3):
        with a:
            with b:
                pass
    assert san.findings("lock-order") == []


def test_rlock_reentrancy_is_not_a_self_cycle(san):
    r = san_rlock("fixture.R")
    with r:
        with r:
            pass
    assert san.findings("lock-order") == []


# -- lockset race detection ---------------------------------------------------


def _bad_counter_cls():
    @shared_state({"counter": "_lock"})
    class Bad:
        def __init__(self):
            self._lock = san_lock("bad._lock")
            self.counter = 0

        def bump_locked(self):
            with self._lock:
                self.counter += 1

        def read_unlocked(self):
            return self.counter

    return Bad


def _good_counter_cls():
    @shared_state({"counter": "_lock"})
    class Good:
        def __init__(self):
            self._lock = san_lock("good._lock")
            self.counter = 0

        def bump(self):
            with self._lock:
                self.counter += 1

        def read(self):
            with self._lock:
                return self.counter

    return Good


def test_race_detector_flags_unlocked_cross_thread_read(san):
    bad = _bad_counter_cls()()
    bad.bump_locked()  # observed from the constructing thread first
    _run_threads(bad.read_unlocked)
    msgs = [f.message for f in san.findings("race")]
    assert any("Bad.counter read from a second thread without _lock held"
               in m for m in msgs)


def test_race_detector_quiet_on_locked_class(san):
    good = _good_counter_cls()()
    _run_threads(*([good.bump] * 2 + [good.read] * 2))
    assert san.findings("race") == []


def test_single_threaded_unlocked_access_is_not_a_race(san):
    bad = _bad_counter_cls()()
    for _ in range(5):
        bad.read_unlocked()
    assert san.findings("race") == []


def test_production_classes_clean_under_two_threads(san):
    """Regression for the audited unguarded reads: PlanHolder counters and
    engine trace counts hammered from two threads produce zero findings."""
    from repro.core.plan_cache import PlanHolder

    holder = PlanHolder(build_plan(retailer_like(scale=20, cols=2)))

    def worker():
        for _ in range(50):
            holder.note_external_append()
            holder.counters()

    _run_threads(worker, worker)
    assert holder.counters()[0] == 100  # 2 threads x 50, none lost
    assert san.findings("race") == []


def test_thread_exit_holding_lock_flagged(san):
    lock = san_lock("fixture.leak")

    def leaky():
        lock.acquire()

    t = san_thread(leaky)
    t.start()
    t.join(timeout=10.0)
    msgs = [f.message for f in san.findings("thread")]
    assert any("exited holding lock" in m and "fixture.leak" in m
               for m in msgs)


# -- retrace attribution ------------------------------------------------------


def test_retrace_attribution_names_diverged_component(san):
    # Numerics off: the f64 shadow would pre-compile the very signature the
    # armed dispatch below is supposed to introduce.
    sanitizer.STATE.numerics = False
    plan = build_plan(retailer_like(scale=20, cols=2))
    engine = FigaroEngine(donate_data=False)
    engine.qr(plan, dtype=jnp.float32)
    engine.qr(plan, dtype=jnp.float32)  # cache hit: no event
    events = [e for e in san_retrace.events() if e.kind == "qr"]
    assert len(events) == 1 and events[0].diverged == []
    assert san.findings("retrace") == []  # warmup compiles are not findings

    sanitizer.expect_no_retrace()
    engine.qr(plan, dtype=jnp.float32)  # steady state: still cached
    assert san.findings("retrace") == []
    engine.qr(plan, dtype=jnp.float64)  # dtype lives in the options component
    msgs = [f.message for f in san.findings("retrace")]
    assert any("retrace of kind=qr" in m and "options" in m for m in msgs)
    last = san_retrace.last_trace("qr")
    assert last is not None and last.diverged == ["options"]


def test_shadow_dispatches_do_not_bump_or_retrace(san):
    """The float64 shadow runs through the same executable cache but must not
    count as a trace or feed the retrace tripwire — otherwise the serving
    zero-retrace contract could not be asserted under FIGARO_SAN=1."""
    plan = build_plan(retailer_like(scale=20, cols=2))
    engine = FigaroEngine(donate_data=False)
    engine.qr(plan, dtype=jnp.float32)  # sampled: shadows through f64
    assert san_numerics.events(), "first dispatch must be shadow-sampled"
    assert engine.trace_count("qr") == 1
    assert all(ev.kind == "qr" for ev in san_retrace.events())


# -- numerics: the paper's database-size error budget -------------------------


@pytest.mark.parametrize("maker", [
    lambda: retailer_like(scale=60, cols=2),
    lambda: yelp_like(scale=40, cols=2),
], ids=["retailer", "yelp"])
def test_f32_error_within_database_size_budget(san, maker):
    """rel_err(f32 vs f64 shadow) <= eps(f32) * slack * database rows — the
    paper's claim that Figaro's rounding error scales with database size."""
    plan = build_plan(maker())
    engine = FigaroEngine(donate_data=False)
    engine.qr(plan, dtype=jnp.float32)
    events = [e for e in san_numerics.events() if e["kind"] == "qr"]
    assert len(events) == 1
    ev = events[0]
    db_rows = san_numerics.database_rows(tuple(plan.data), plan)
    assert ev["db_rows"] == db_rows and db_rows > 0
    assert ev["budget"] == pytest.approx(
        float(np.finfo(np.float32).eps) * sanitizer.STATE.numerics_slack
        * db_rows)
    assert 0.0 <= ev["rel_err"] <= ev["budget"]
    assert san.findings("numerics") == []


def test_nan_input_trips_nonfinite_tripwire(san):
    plan = build_plan(retailer_like(scale=20, cols=2))
    engine = FigaroEngine(donate_data=False)
    data = [np.array(d, dtype=np.float64, copy=True) for d in plan.data]
    data[0][0, 0] = np.nan
    engine.qr(plan, tuple(data), dtype=jnp.float32)
    msgs = [f.message for f in san.findings("numerics")]
    assert any("non-finite" in m and "kind=qr" in m for m in msgs)


def test_numerics_sampling_skips_unsampled_dispatches(san):
    sanitizer.STATE.sample_every = 1000
    plan = build_plan(retailer_like(scale=20, cols=2))
    engine = FigaroEngine(donate_data=False)
    engine.qr(plan, dtype=jnp.float32)  # first dispatch always shadows
    engine.qr(plan, dtype=jnp.float32)  # 2nd of 1000: not sampled
    assert len([e for e in san_numerics.events() if e["kind"] == "qr"]) == 1
