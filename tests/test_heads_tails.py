"""Paper §3: heads/tails are the closed forms of Givens-rotation sequences."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.heads_tails import (givens_sequence, head, segmented_cumsum,
                                    segmented_head_tail, tail)


def _rand(rng, *shape):
    return rng.normal(size=shape)


# -- Lemma 3.3 (v = 1) and Lemma 3.5 (weighted) vs explicit rotations --------


@pytest.mark.parametrize("m,n1,n2", [(2, 1, 1), (3, 2, 2), (7, 2, 3),
                                     (16, 1, 5)])
def test_lemma35_vs_explicit_rotations(rng, m, n1, n2):
    s = _rand(rng, 1, n1)
    t = _rand(rng, m, n2)
    v = rng.uniform(0.5, 2.0, size=m)
    a = np.concatenate([v[:, None] * s, t], axis=1)
    g = givens_sequence(v)
    u = g @ a
    # top row: [ ||v|| * S | head(T, v) ]
    expect_top = np.concatenate([np.linalg.norm(v) * s[0],
                                 np.asarray(head(jnp.array(t), jnp.array(v)))])
    np.testing.assert_allclose(u[0], expect_top, atol=1e-12)
    # S-columns below the top row are zeroed
    np.testing.assert_allclose(u[1:, :n1], 0, atol=1e-12)
    # T-columns below the top row are tail(T, v)
    np.testing.assert_allclose(
        u[1:, n1:], np.asarray(tail(jnp.array(t), jnp.array(v))), atol=1e-12)


def test_lemma33_unweighted_is_v_equals_one(rng):
    t = _rand(rng, 9, 4)
    ones = jnp.ones(9)
    np.testing.assert_allclose(np.asarray(head(jnp.array(t))),
                               np.asarray(head(jnp.array(t), ones)), atol=0)
    np.testing.assert_allclose(np.asarray(tail(jnp.array(t))),
                               np.asarray(tail(jnp.array(t), ones)), atol=0)


def test_rotation_sequence_is_orthogonal(rng):
    v = rng.uniform(0.1, 3.0, size=12)
    g = givens_sequence(v)
    np.testing.assert_allclose(g @ g.T, np.eye(12), atol=1e-12)


def test_head_tail_preserve_gram(rng):
    """[head; tail] stacked with the scaled-S row is an orthogonal transform
    of [S⊗v | A]: Frobenius norm and Gram matrix are preserved."""
    a = _rand(rng, 11, 5)
    v = rng.uniform(0.5, 2.0, size=11)
    s = _rand(rng, 1, 2)
    m = np.concatenate([v[:, None] * s, a], axis=1)
    g = givens_sequence(v)
    np.testing.assert_allclose(np.linalg.norm(g @ m), np.linalg.norm(m),
                               rtol=1e-12)
    h = np.asarray(head(jnp.array(a), jnp.array(v)))
    t = np.asarray(tail(jnp.array(a), jnp.array(v)))
    top = np.concatenate([np.linalg.norm(v) * s[0], h])
    rest = np.concatenate([np.zeros((10, 2)), t], axis=1)
    u = np.concatenate([top[None, :], rest], axis=0)
    np.testing.assert_allclose(u.T @ u, m.T @ m, rtol=1e-10, atol=1e-10)


def test_lemma37_scaling(rng):
    """H(kA, l v) = k H(A, v); same for tails (Lemma 3.7)."""
    a = jnp.array(_rand(rng, 6, 3))
    v = jnp.array(rng.uniform(0.5, 2.0, size=6))
    k, l = 2.5, 3.0
    np.testing.assert_allclose(np.asarray(head(k * a, l * v)),
                               k * np.asarray(head(a, v)), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(tail(k * a, l * v)),
                               k * np.asarray(tail(a, v)), rtol=1e-12)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16, jnp.float32,
                                   jnp.float64])
def test_head_tail_preserve_dtype(rng, dtype):
    """Mirrors the normalize_sign dtype test: the weight vector is cast to
    the data dtype, so a float64 v must not silently upcast low-precision
    (bf16/f16/f32) data through `head` (tail already cast)."""
    a = jnp.asarray(_rand(rng, 6, 3), dtype=dtype)
    v = jnp.asarray(rng.uniform(0.5, 2.0, size=6))  # float64 weights
    h = head(a, v)
    t = tail(a, v)
    assert h.dtype == dtype, (h.dtype, dtype)
    assert t.dtype == dtype, (t.dtype, dtype)


# -- property test: the transform is orthogonal for arbitrary inputs ---------


@settings(max_examples=40, deadline=None)
@given(m=st.integers(2, 20), n=st.integers(1, 6), seed=st.integers(0, 2**31))
def test_property_gram_preserved(m, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n))
    v = rng.uniform(0.1, 4.0, size=m)
    h = np.asarray(head(jnp.array(a), jnp.array(v)))
    t = np.asarray(tail(jnp.array(a), jnp.array(v)))
    u = np.concatenate([h[None, :], t], axis=0)
    # U = G' A for orthogonal G' acting on the weighted stack; Gram of the
    # *weighted* matrix [v⊗1 ⊙ A] is NOT preserved, but Lemma 3.5 says
    # U^T U == A^T A when v == 1; for general v the invariant involves S too.
    if np.allclose(v, v[0]):
        np.testing.assert_allclose(u.T @ u, a.T @ a, rtol=1e-9, atol=1e-9)
    # Always: stacking with the scaled S column preserves the full Gram.
    s = rng.normal(size=(1, 2))
    mfull = np.concatenate([v[:, None] * s, a], axis=1)
    top = np.concatenate([np.linalg.norm(v) * s[0], h])
    rest = np.concatenate([np.zeros((m - 1, 2)), t], axis=1)
    ufull = np.concatenate([top[None, :], rest], axis=0)
    np.testing.assert_allclose(ufull.T @ ufull, mfull.T @ mfull,
                               rtol=1e-8, atol=1e-8)


# -- segmented version --------------------------------------------------------


def test_segmented_cumsum_restarts(rng):
    x = jnp.array(rng.normal(size=10))
    first = jnp.array([1, 0, 0, 1, 0, 1, 0, 0, 0, 1], bool)
    out = np.asarray(segmented_cumsum(x, first))
    expect = np.empty(10)
    acc = 0.0
    for i in range(10):
        acc = float(x[i]) if bool(first[i]) else acc + float(x[i])
        expect[i] = acc
    np.testing.assert_allclose(out, expect, rtol=1e-12)


def test_segmented_head_tail_matches_per_segment(rng):
    sizes = [3, 1, 5, 2]
    data = _rand(rng, sum(sizes), 4)
    w = rng.uniform(0.5, 2.0, size=sum(sizes))
    seg = np.repeat(np.arange(len(sizes)), sizes)
    pos = np.concatenate([np.arange(s) for s in sizes])
    heads, tails, norms = segmented_head_tail(
        jnp.array(data), jnp.array(w), jnp.array(seg), jnp.array(pos),
        len(sizes))
    ofs = 0
    for k, s in enumerate(sizes):
        blk, vb = data[ofs:ofs + s], w[ofs:ofs + s]
        np.testing.assert_allclose(np.asarray(heads[k]),
                                   np.asarray(head(jnp.array(blk),
                                                   jnp.array(vb))), rtol=1e-10)
        np.testing.assert_allclose(np.asarray(norms[k]), np.linalg.norm(vb),
                                   rtol=1e-12)
        if s > 1:
            np.testing.assert_allclose(
                np.asarray(tails[ofs + 1:ofs + s]),
                np.asarray(tail(jnp.array(blk), jnp.array(vb))), rtol=1e-9)
        # first row of each segment carries no tail
        np.testing.assert_allclose(np.asarray(tails[ofs]), 0, atol=0)
        ofs += s
