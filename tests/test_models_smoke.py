"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU, asserting output shapes + no NaNs — as the task spec requires."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state, make_train_step


def _batch(cfg, b=2, s=32, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (b, s), 0, cfg.vocab)}
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_len, cfg.d_model),
            jnp.bfloat16)
    if cfg.patch_positions:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.patch_positions, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = get_config(name, smoke=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, aux, offset = jax.jit(
        lambda p, bt: tf.forward(p, cfg, bt))(params, batch)
    total = s + cfg.patch_positions
    assert logits.shape == (b, total, cfg.padded_vocab), logits.shape
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step(name):
    cfg = get_config(name, smoke=True)
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, mesh))
    with mesh:
        new_state, metrics = step(state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (name, loss)
    assert int(new_state.step) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_state.params),
        jax.tree_util.tree_leaves(state.params)))
    assert delta > 0, name
    # no NaNs anywhere in the updated state
    for leaf in jax.tree_util.tree_leaves(new_state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), name


def test_full_configs_match_assignment():
    """Exact architecture numbers from the task table."""
    expect = {
        "jamba-v0.1-52b": dict(d_model=4096, d_ff=14336, vocab=65536,
                               layers=32, moe=16),
        "whisper-tiny": dict(d_model=384, d_ff=1536, vocab=51865, layers=8),
        "arctic-480b": dict(d_model=7168, d_ff=4864, vocab=32000, layers=35,
                            moe=128),
        "mixtral-8x22b": dict(d_model=6144, d_ff=16384, vocab=32768,
                              layers=56, moe=8),
        "minicpm-2b": dict(d_model=2304, d_ff=5760, vocab=122753, layers=40),
        "command-r-35b": dict(d_model=8192, d_ff=22528, vocab=256000,
                              layers=40),
        "granite-3-8b": dict(d_model=4096, d_ff=12800, vocab=49155,
                             layers=40),
        "qwen3-8b": dict(d_model=4096, d_ff=12288, vocab=151936, layers=36),
        "llava-next-34b": dict(d_model=7168, d_ff=20480, vocab=64000,
                               layers=60),
        "rwkv6-1.6b": dict(d_model=2048, d_ff=7168, vocab=65536, layers=24),
    }
    for name, exp in expect.items():
        cfg = get_config(name)
        assert cfg.d_model == exp["d_model"], name
        assert cfg.d_ff == exp["d_ff"], name
        assert cfg.vocab == exp["vocab"], name
        assert cfg.n_layers == exp["layers"], (name, cfg.n_layers)
        if "moe" in exp:
            assert cfg.moe is not None and cfg.moe.num_experts == exp["moe"]
    # family-specific details
    assert get_config("qwen3-8b").qk_norm
    assert get_config("mixtral-8x22b").swa_window is not None
    assert get_config("rwkv6-1.6b").block[0].mixer == "rwkv6"
    jamba = get_config("jamba-v0.1-52b")
    mixers = [s.mixer for s in jamba.block]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    arctic = get_config("arctic-480b")
    assert arctic.block[0].mlp == "dense+moe"  # dense residual + MoE


def test_param_counts_in_expected_range():
    """Analytic param counts are in the ballpark of the arch names."""
    expect_b = {"jamba-v0.1-52b": (45, 60), "arctic-480b": (400, 520),
                "mixtral-8x22b": (120, 160), "minicpm-2b": (2, 4),
                "command-r-35b": (30, 40), "granite-3-8b": (7, 10),
                "qwen3-8b": (6.5, 10), "llava-next-34b": (30, 40),
                # rwkv6 lands above its marketing name because the ASSIGNED
                # dims (d_ff=7168, vocab=65536) are wider than the hf release
                "rwkv6-1.6b": (1.2, 2.4), "whisper-tiny": (0.02, 0.08)}
    for name, (lo, hi) in expect_b.items():
        n = get_config(name).param_count() / 1e9
        assert lo <= n <= hi, (name, n)
