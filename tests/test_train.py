"""Training-loop behaviour: learning, microbatching, schedules, compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compressed_psum, init_residual
from repro.optim.orthogonal import orthogonalize
from repro.optim.schedules import warmup_cosine, wsd
from repro.train.step import init_state, make_eval_step, make_train_step


def test_loss_decreases_on_learnable_data():
    cfg = get_config("granite-3-8b", smoke=True)
    cfg = dataclasses.replace(cfg, remat=False)
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    pipe = TokenPipeline(cfg.vocab, seq_len=64, global_batch=8, seed=0)
    step = jax.jit(make_train_step(cfg, opt_cfg, mesh))
    losses = []
    with mesh:
        for s in range(30):
            state, metrics = step(state, pipe.batch_at(s))
            losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_equivalence():
    """grad-accumulated microbatching == single big batch (same update)."""
    cfg = get_config("qwen3-8b", smoke=True)
    cfg = dataclasses.replace(cfg, remat=False, compute_dtype="float32")
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 32), 0,
                                          cfg.vocab)}
    with mesh:
        s1, m1 = jax.jit(make_train_step(cfg, opt_cfg, mesh))(state, batch)
        s2, m2 = jax.jit(make_train_step(cfg, opt_cfg, mesh,
                                         microbatch=2))(state, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-6)


def test_eval_step_runs():
    cfg = get_config("granite-3-8b", smoke=True)
    mesh = make_host_mesh()
    params = init_state(jax.random.PRNGKey(0), cfg, AdamWConfig()).params
    ev = jax.jit(make_eval_step(cfg, mesh))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab)}
    with mesh:
        metrics = ev(params, batch)
    assert np.isfinite(float(metrics["loss"]))


# -- optimizer unit tests ------------------------------------------------------


def test_adamw_matches_manual_reference(rng):
    p = {"w": jnp.array(rng.normal(size=(4, 3)), jnp.float32)}
    g = {"w": jnp.array(rng.normal(size=(4, 3)), jnp.float32) * 0.01}
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9)
    st = adamw_init(p, cfg)
    new_p, st, _ = adamw_update(g, st, p, cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    expect = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)


def test_adamw_clipping():
    p = {"w": jnp.ones((2, 2), jnp.float32)}
    g = {"w": jnp.full((2, 2), 100.0, jnp.float32)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    st = adamw_init(p, cfg)
    _, _, metrics = adamw_update(g, st, p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    fn = warmup_cosine(1.0, warmup=10, total=110)
    assert float(fn(jnp.array(0))) == 0.0
    assert float(fn(jnp.array(10))) == pytest.approx(1.0, rel=1e-5)
    assert float(fn(jnp.array(110))) == pytest.approx(0.1, rel=1e-4)
    fn = wsd(1.0, warmup=10, stable=50, decay=40, floor=0.01)
    assert float(fn(jnp.array(5))) == pytest.approx(0.5)
    assert float(fn(jnp.array(30))) == pytest.approx(1.0)
    assert float(fn(jnp.array(100))) == pytest.approx(0.01, rel=1e-3)
    # plateau really is flat (WSD's continued-pretraining property)
    assert float(fn(jnp.array(12))) == float(fn(jnp.array(58))) == 1.0


def test_orthogonalize_produces_orthonormal_frame(rng):
    g = jnp.array(rng.normal(size=(64, 16)), jnp.float32)
    q = np.asarray(orthogonalize(g))
    gram = q.T @ q / q.shape[1]  # RMS-scaled: QᵀQ == n·I
    np.testing.assert_allclose(gram, np.eye(16), atol=5e-3)


def test_compressed_psum_error_feedback(rng):
    """int8+EF all-reduce: single-step error bounded; residual carries it."""
    mesh = make_host_mesh()  # 1 device -> axis size 1: exactness check
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g = {"w": jnp.array(rng.normal(size=(8, 8)), jnp.float32)}
    r = init_residual(g)

    def f(gg, rr):
        return compressed_psum(gg, rr, "data")

    out, res = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()))(g, r)
    # with one participant the only error is quantization; EF captures it
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(res["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err < np.abs(np.asarray(g["w"])).max() / 64  # ~int8 resolution
