"""Concurrency stress under FIGARO_SAN: several threads hammer ONE
`AsyncFigaroServer` with interleaved submit / append / stats while the full
runtime sanitizer (lockset race detector, lock-order graph, retrace tripwire
after warmup) is armed. The contract: zero detector findings, every future
resolves, and resolution order preserves per-thread submission order.

The CI analysis job runs this file with ``FIGARO_SAN=1`` in the environment;
standalone runs arm the sanitizer through the fixture, so the assertion is
identical either way."""

import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro import figaro, sanitizer

N_SUBMITTERS = 3
SUBMITS_PER_THREAD = 5
N_APPENDS = 3
N_STATS_READERS = 2


@pytest.fixture
def san():
    sanitizer.enable()
    sanitizer.reset()
    yield sanitizer
    sanitizer.reset()
    sanitizer.disable()


def _star_ds(session):
    rng = np.random.default_rng(7)
    tables = {
        "Orders": ({"cust": np.arange(20) % 8, "prod": np.arange(20) % 4},
                   rng.normal(size=(20, 2)), ["amount", "qty"]),
        "Customers": ({"cust": np.arange(8)},
                      rng.normal(size=(8, 2)), ["age", "income"]),
        "Products": ({"prod": np.arange(4)},
                     rng.normal(size=(4, 1)), ["price"]),
    }
    return session.ingest(tables).join(
        "Orders", [("Orders", "Customers"), ("Orders", "Products")])


def test_threaded_submit_append_stats_zero_findings(san):
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    server = ds.serve(kind="qr", dtype=jnp.float64, max_batch=4)

    # Warm every batch bucket the storm can coalesce into (capacities 1, 2
    # and 4 with max_batch=4), THEN arm the retrace tripwire: any further
    # compile during the storm is a finding with signature attribution.
    warm = lambda: tuple(np.asarray(d) for d in ds.plan.data)
    for group in (1, 2, 3):
        server.pause()
        futs = [server.submit(warm()) for _ in range(group)]
        server.resume()
        for f in futs:
            np.asarray(f.result(timeout=120))
    sanitizer.expect_no_retrace()

    resolved = []  # (submitter_id, seq), appended in resolution order
    resolved_lock = threading.Lock()
    errors = []

    def record(tid, seq):
        def cb(fut):
            with resolved_lock:
                resolved.append((tid, seq))
        return cb

    n = ds.plan.num_cols

    def submitter(tid):
        rng = np.random.default_rng(tid)
        try:
            futures = []
            for seq in range(SUBMITS_PER_THREAD):
                req = tuple(rng.normal(size=np.asarray(d).shape)
                            for d in ds.plan.data)
                fut = server.submit(req)
                fut.add_done_callback(record(tid, seq))
                futures.append(fut)
            for fut in futures:
                r = np.asarray(fut.result(timeout=120))
                assert r.shape == (n, n)
        except BaseException as e:  # surfaced after the join below
            errors.append(e)

    def appender():
        try:
            for step in range(N_APPENDS):
                in_cap = server.append(
                    "Orders", ({"cust": np.array([step]),
                                "prod": np.array([step % 4])},
                               np.ones((1, 2)) * step))
                assert in_cap, "append within headroom must stay in capacity"
        except BaseException as e:
            errors.append(e)

    def stats_reader():
        try:
            for _ in range(20):
                st = ds.stats()
                assert st["nodes"]["Orders"]["live_rows"] >= 20
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=submitter, args=(tid,))
               for tid in range(N_SUBMITTERS)]
    threads.append(threading.Thread(target=appender))
    threads += [threading.Thread(target=stats_reader)
                for _ in range(N_STATS_READERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300.0)
    assert not any(t.is_alive() for t in threads), "stress thread hung"
    assert errors == [], errors

    server.flush()
    server.close()

    # Per-thread submission order is preserved in resolution order: the
    # completion thread resolves futures in dispatch order, and each
    # submitter's stream is sequential.
    with resolved_lock:
        done = list(resolved)
    assert len(done) == N_SUBMITTERS * SUBMITS_PER_THREAD
    for tid in range(N_SUBMITTERS):
        seqs = [seq for t, seq in done if t == tid]
        assert seqs == sorted(seqs), \
            f"thread {tid} futures resolved out of submission order: {seqs}"

    # The tentpole assertion: the whole storm ran under the armed sanitizer
    # with nothing to report.
    assert sanitizer.findings() == [], "\n" + sanitizer.report()

    st = ds.stats()
    assert st["appends"] == N_APPENDS and st["regrows"] == 0


def test_two_servers_one_holder_under_sanitizer(san):
    """Sibling servers share the PlanHolder; appends through one must stay
    race-free and visible through the other while both dispatch."""
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    s1 = ds.serve(kind="qr", dtype=jnp.float64)
    s2 = ds.serve(kind="qr", dtype=jnp.float64)
    req = lambda: tuple(np.asarray(d) for d in ds.plan.data)

    def pump(server):
        for _ in range(3):
            np.asarray(server.submit(req()).result(timeout=120))

    t1 = threading.Thread(target=pump, args=(s1,))
    t2 = threading.Thread(target=pump, args=(s2,))
    t1.start(); t2.start()
    t1.join(timeout=300.0); t2.join(timeout=300.0)
    assert s1.append("Orders", ({"cust": np.array([0]),
                                 "prod": np.array([0])}, np.ones((1, 2))))
    assert ds.plan is s2.plan, "holder forked between sibling servers"
    s1.close()
    s2.close()
    assert sanitizer.findings() == [], "\n" + sanitizer.report()
