"""Subprocess driver for the sharded serving + distributed combine tests.

Run: ``PYTHONPATH=src python tests/_sharded_driver.py <num_devices>``.
Invoked by test_sharding.py in a fresh process for non-power-of-two (3) and
power-of-two (4) forced host device counts — the XLA device count must be
pinned before jax initializes, so this cannot run in-process with the suite.

Covers the acceptance criteria of the sharded-dispatch PR:
  * sharded batched qr/svd/pca/least_squares match the per-sample engine
    results (sign-normalized R comparison + Gram invariant), including a
    batch size that does NOT divide the mesh (the pad/bucket path);
  * trace counters: one compilation per (plan signature, mesh signature) —
    repeat dispatches and bucketed batch sizes are launch-only, a sub-mesh
    retraces;
  * `butterfly_qr_combine` / `distributed_postprocess_r0` on a
    non-power-of-two mesh axis;
  * `partition_fact_table` with ``num_parts`` larger than the number of fact
    key groups, and `partitioned_figaro_qr` dispatched through the mesh.
"""

import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 3
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.distributed import (distributed_postprocess_r0,  # noqa: E402
                                    distributed_qr_r, partition_fact_table,
                                    partitioned_figaro_qr)
from repro.core.engine import FigaroEngine  # noqa: E402
from repro.core.figaro import figaro_r0  # noqa: E402
from repro.core.join_tree import JoinTree, build_plan  # noqa: E402
from repro.core.materialize import materialize_join  # noqa: E402
from repro.core.postprocess import normalize_sign  # noqa: E402
from repro.core.relation import Database, full_reduce  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402
from repro.train.serve import make_figaro_server  # noqa: E402


def star_tree(rng):
    tables = {
        "F": ({"a": rng.integers(0, 6, 40), "b": rng.integers(0, 4, 40)},
              rng.normal(size=(40, 2)), ["f0", "f1"]),
        "D1": ({"a": rng.integers(0, 6, 18)}, rng.normal(size=(18, 2)),
               ["d0", "d1"]),
        "D2": ({"b": rng.integers(0, 4, 12)}, rng.normal(size=(12, 1)),
               ["e0"]),
    }
    db = Database.from_arrays(tables)
    edges = [("F", "D1"), ("F", "D2")]
    db = full_reduce(db, edges)
    return JoinTree.from_edges(db, "F", edges)


def check_sharded_serving(rng, mesh) -> None:
    tree = star_tree(rng)
    plan = build_plan(tree)
    n = plan.num_cols
    b = N_DEV + 2  # not a multiple of the mesh -> exercises the pad path
    batch = tuple(
        np.stack([rng.normal(size=np.asarray(d).shape) for _ in range(b)])
        for d in plan.data)
    engine = FigaroEngine(donate_data=False)
    ref = FigaroEngine(donate_data=False)

    # --- qr: values + Gram invariant + trace accounting ---------------------
    rb = np.asarray(engine.qr(plan, batch, batched=True, shard=mesh,
                              dtype=jnp.float64))
    assert rb.shape == (b, n, n), rb.shape
    assert engine.trace_count("qr_batched") == 1
    for i in range(b):
        ri = np.asarray(ref.qr(plan, [d[i] for d in batch],
                               dtype=jnp.float64))
        scale = max(np.abs(ri).max(), 1.0)
        assert np.abs(rb[i] - ri).max() / scale < 1e-10, ("qr", i)
        r0i = np.asarray(figaro_r0(plan, [d[i] for d in batch],
                                   dtype=jnp.float64))
        g = r0i.T @ r0i  # == A_iᵀA_i (tier-1-validated invariant)
        gerr = np.abs(rb[i].T @ rb[i] - g).max() / max(np.abs(g).max(), 1e-30)
        assert gerr < 1e-10, ("gram", i, gerr)

    # Repeat dispatch and a bucketed smaller batch: launch-only.
    engine.qr(plan, batch, batched=True, shard=mesh, dtype=jnp.float64)
    engine.qr(plan, tuple(d[: b - 1] for d in batch), batched=True,
              shard=mesh, dtype=jnp.float64)
    assert engine.trace_count("qr_batched") == 1, "bucketed batch retraced"
    # A sub-mesh is a new mesh signature -> exactly one more compilation.
    if N_DEV > 1:
        sub = make_data_mesh(N_DEV - 1)
        engine.qr(plan, batch, batched=True, shard=sub, dtype=jnp.float64)
        assert engine.trace_count("qr_batched") == 2, "mesh signature ignored"

    # --- svd ----------------------------------------------------------------
    s_b, vt_b = engine.svd(plan, batch, batched=True, shard=mesh,
                           dtype=jnp.float64)
    s_b, vt_b = np.asarray(s_b), np.asarray(vt_b)
    for i in range(b):
        s_i, vt_i = ref.svd(plan, [d[i] for d in batch], dtype=jnp.float64)
        assert np.abs(s_b[i] - np.asarray(s_i)).max() < 1e-9, ("svd s", i)
        # right-singular vectors match up to per-row sign
        sgn = np.sign(np.sum(vt_b[i] * np.asarray(vt_i), axis=1))[:, None]
        assert np.abs(vt_b[i] * sgn - np.asarray(vt_i)).max() < 1e-8, \
            ("svd vt", i)

    # --- pca / least_squares through the batched server ---------------------
    serve_lsq = make_figaro_server(plan, kind="lsq", label_col=n - 1,
                                   ridge=0.25, dtype=jnp.float64,
                                   engine=engine, mesh=mesh)
    betas, resids = serve_lsq(batch)
    assert engine.trace_count("least_squares_batched") == 1
    assert engine.trace_count("least_squares") == 0, \
        "lsq server fell back to per-sample dispatch"
    for i in range(b):
        b_i, r_i = ref.least_squares(plan, n - 1, [d[i] for d in batch],
                                     ridge=0.25, dtype=jnp.float64)
        assert np.abs(np.asarray(betas[i]) - np.asarray(b_i)).max() < 1e-9
        assert abs(float(resids[i]) - float(r_i)) < 1e-9

    pca_b = engine.pca(plan, batch, batched=True, shard=mesh, k=3,
                       dtype=jnp.float64)
    ev = np.asarray(pca_b.explained_variance)
    assert ev.shape == (b, 3) and (ev >= 0).all()
    for i in range(b):
        pca_i = ref.pca(plan, [d[i] for d in batch], k=3, dtype=jnp.float64)
        assert np.abs(ev[i] - np.asarray(pca_i.explained_variance)).max() \
            < 1e-9, ("pca ev", i)
        assert np.abs(np.asarray(pca_b.mean[i])
                      - np.asarray(pca_i.mean)).max() < 1e-10, ("pca mean", i)


def check_distributed_combine(rng, mesh) -> None:
    # Non-power-of-two (N_DEV=3) and power-of-two (N_DEV=4) butterfly.
    x = jnp.array(rng.normal(size=(257, 9)))  # odd rows: shard padding too
    r = np.asarray(normalize_sign(distributed_qr_r(x, mesh, "data")))
    r_ref = np.asarray(normalize_sign(jnp.linalg.qr(x, mode="r")))
    assert np.abs(r - r_ref).max() < 1e-10 * np.abs(r_ref).max()

    tree = star_tree(rng)
    plan = build_plan(tree)
    a = materialize_join(tree)
    r_ref = np.asarray(normalize_sign(jnp.linalg.qr(jnp.array(a), mode="r")))
    r0 = figaro_r0(plan, dtype=jnp.float64)
    r_dist = np.asarray(distributed_postprocess_r0(r0, mesh, "data"))
    err = np.abs(r_dist - r_ref).max() / np.abs(r_ref).max()
    assert err < 1e-10, ("distributed_postprocess_r0", err)


def check_partitioned(rng, mesh) -> None:
    tree = star_tree(rng)
    a = materialize_join(tree)
    r_ref = np.asarray(normalize_sign(jnp.linalg.qr(jnp.array(a), mode="r")))
    m = tree.db["F"].num_rows

    # num_parts far beyond the number of fact key groups: every group becomes
    # (at most) its own partition, empties are dropped, nothing is lost.
    parts = partition_fact_table(tree, 10 * m)
    assert 0 < len(parts) <= 10 * m
    assert sum(t.db["F"].num_rows for t in parts) == m

    for num_parts in (N_DEV, 10 * m):
        r = np.asarray(partitioned_figaro_qr(tree, num_parts, mesh=mesh))
        err = np.abs(r - r_ref).max() / np.abs(r_ref).max()
        assert err < 1e-10, ("partitioned_figaro_qr", num_parts, err)


def main() -> None:
    assert len(jax.devices()) == N_DEV, jax.devices()
    rng = np.random.default_rng(7)
    mesh = make_data_mesh()
    assert mesh.shape["data"] == N_DEV
    check_sharded_serving(rng, mesh)
    check_distributed_combine(rng, mesh)
    check_partitioned(rng, mesh)
    print(f"SHARDED-OK {N_DEV}")


if __name__ == "__main__":
    main()
