"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the deliverable: shape/dtype sweeps asserting allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.heads_tails import segmented_head_tail
from repro.core.postprocess import blocked_qr_r, normalize_sign
from repro.kernels.head_tail import ops as ht_ops, ref as ht_ref
from repro.kernels.panel_qr import ops as pq_ops, ref as pq_ref


# -- head_tail ----------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(5, 3), (37, 9), (64, 128), (300, 40),
                                 (513, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_head_tail_kernel_sweep(rng, m, n, dtype):
    data = jnp.array(rng.normal(size=(m, n)), dtype)
    v = jnp.array(rng.uniform(0.5, 2.0, size=(m,)), dtype)
    first = np.zeros(m)
    first[0] = 1
    first[rng.random(m) < 0.2] = 1
    wa = data * v[:, None]
    ca = jnp.array(rng.normal(size=(m, 1)), dtype)
    cb = jnp.array(rng.normal(size=(m, 1)), dtype)
    f = jnp.array(first[:, None], dtype)
    out_k = ht_ops.segmented_tail(data, wa, f, ca, cb,
                                  block_rows=64, block_cols=128)
    out_r = ht_ref.segmented_tail_ref(data, wa, f, ca, cb)
    err = np.abs(np.asarray(out_k) - np.asarray(out_r)).max()
    assert err < 1e-4, err


def test_head_tail_kernel_integrated(rng):
    """segmented_head_tail(use_kernel=True) == pure-jnp path."""
    m, n = 200, 17
    data = jnp.array(rng.normal(size=(m, n)), jnp.float32)
    w = jnp.array(rng.uniform(0.5, 2.0, size=m), jnp.float32)
    seg = np.sort(rng.integers(0, 12, size=m)).astype(np.int32)
    pos = np.zeros(m, np.int32)
    for i in range(1, m):
        pos[i] = pos[i - 1] + 1 if seg[i] == seg[i - 1] else 0
    args = (data, w, jnp.array(seg), jnp.array(pos), 12)
    h1, t1, n1 = segmented_head_tail(*args, use_kernel=False)
    h2, t2, n2 = segmented_head_tail(*args, use_kernel=True)
    assert np.abs(np.asarray(t1) - np.asarray(t2)).max() < 1e-4
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-6)


@pytest.mark.parametrize("m,block_rows", [(65, 64), (129, 64), (237, 32),
                                          (100, 64)])
def test_segmented_tail_rows_straddle_block_boundary(rng, m, block_rows):
    """`m` not a multiple of `block_rows`, with segments crossing every row
    block: the kernel's carried-prefix path (interpret mode) must agree with
    the XLA associative-scan path row for row."""
    from repro.core.heads_tails import segmented_cumsum

    n = 24
    data = jnp.array(rng.normal(size=(m, n)), jnp.float32)
    w = jnp.array(rng.uniform(0.5, 2.0, size=m), jnp.float32)
    # long segments (~1.5 blocks) so nearly every block boundary falls inside
    # a segment, plus a trailing remnant segment in the partial block
    bounds = list(range(0, m, max(3 * block_rows // 2, 2))) + [m]
    pos = np.concatenate([np.arange(b - a) for a, b in zip(bounds, bounds[1:])])
    first = (pos == 0).astype(np.float32)
    assert any(f == 0 and (i % block_rows) == 0 for i, f in enumerate(first)
               if i), "no segment straddles a block boundary"

    w2 = w * w
    wa = data * w[:, None]
    c_incl = segmented_cumsum(w2, jnp.array(first, bool))
    c_excl = c_incl - w2
    c_excl_safe = jnp.where(jnp.array(pos) > 0, c_excl, 1.0)
    coef_a = jnp.sqrt(c_excl_safe / c_incl)
    coef_b = -w / jnp.sqrt(c_excl_safe * c_incl)

    out_kernel = ht_ops.segmented_tail(
        data, wa, jnp.array(first), coef_a, coef_b,
        block_rows=block_rows, block_cols=128)
    # XLA associative-scan path: same coefficients applied to the segmented
    # exclusive prefix sum (this is segmented_head_tail's non-kernel branch)
    s_excl = segmented_cumsum(wa, jnp.array(first, bool)) - wa
    out_xla = coef_a[:, None] * data + coef_b[:, None] * s_excl
    live = np.asarray(pos) > 0  # rows at segment starts are garbage by spec
    err = np.abs(np.asarray(out_kernel)[live] - np.asarray(out_xla)[live]).max()
    assert err < 1e-4, err


def test_head_tail_kernel_single_row_segments(rng):
    """Degenerate case: every row its own segment -> all tails zero."""
    m, n = 16, 8
    data = jnp.array(rng.normal(size=(m, n)), jnp.float32)
    w = jnp.ones((m,), jnp.float32)
    seg = jnp.arange(m, dtype=jnp.int32)
    pos = jnp.zeros(m, jnp.int32)
    h, t, norms = segmented_head_tail(data, w, seg, pos, m, use_kernel=True)
    np.testing.assert_allclose(np.asarray(t), 0, atol=0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(data), rtol=1e-6)


# -- panel_qr -----------------------------------------------------------------


@pytest.mark.parametrize("m,nb", [(8, 4), (64, 16), (200, 32), (256, 128)])
def test_panel_qr_kernel_sweep(rng, m, nb):
    a = jnp.array(rng.normal(size=(m, nb)), jnp.float32)
    v1, b1, r1 = pq_ops.panel_qr(a)
    v2, b2, r2 = pq_ref.panel_qr_ref(a)
    assert np.abs(np.asarray(v1) - np.asarray(v2)).max() < 2e-3
    assert np.abs(np.asarray(b1) - np.asarray(b2)).max() < 2e-3
    assert np.abs(np.asarray(r1) - np.asarray(r2)).max() < 2e-3


def test_panel_qr_r_is_valid_qr(rng):
    """R from the kernel agrees with lapack on the same panel (up to sign)."""
    a32 = rng.normal(size=(96, 16)).astype(np.float32)
    _, _, r = pq_ops.panel_qr(jnp.array(a32))
    r_np = np.triu(np.asarray(r)[:16])
    ref = np.linalg.qr(a32)[1]
    flip = np.sign(np.diag(r_np)) * np.sign(np.diag(ref))
    np.testing.assert_allclose(r_np * flip[:, None], ref, atol=5e-4)


def test_blocked_qr_with_kernel_path(rng):
    x = jnp.array(rng.normal(size=(300, 64)), jnp.float32)
    rk = normalize_sign(blocked_qr_r(x, panel=32, use_kernel=True))
    rr = normalize_sign(jnp.linalg.qr(x, mode="r"))
    assert np.abs(np.asarray(rk) - np.asarray(rr)).max() < 5e-3
