"""Paper §5 / Algorithm 1: batched group-by counts over the join tree."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.counts import compute_counts, compute_counts_reference
from repro.core.materialize import materialize_join

from helpers import TOPOLOGIES, random_acyclic_db


@pytest.mark.parametrize("topology", list(TOPOLOGIES))
def test_counts_match_exact_reference(rng, topology):
    _, _, plan = random_acyclic_db(topology, rng)
    cj = compute_counts(plan, dtype=jnp.float64)
    cr = compute_counts_reference(plan)
    for i in range(len(plan.nodes)):
        for k in ("rpk", "theta_down", "full", "phi_circ", "phi_up"):
            if k in cr[i]:
                np.testing.assert_allclose(np.asarray(cj[i][k]), cr[i][k],
                                           rtol=1e-12, err_msg=f"node{i}:{k}")


def test_full_join_size_equals_materialized(rng):
    """FULL_JOIN_SIZE summed over the root's groups == |A| (join row count)."""
    db, tree, plan = random_acyclic_db("snowflake4", rng)
    a = materialize_join(tree)
    cr = compute_counts_reference(plan)
    root = plan.preorder[0]
    assert int(cr[root]["full"].sum()) == a.shape[0]


def test_phi_circ_semantics_bruteforce(rng):
    """Φ°_i(x̄_i) == size of the join of all relations except S_i at that key.

    Brute-force check on a snowflake: remove one relation's *data* rows but
    keep the key multiplicity 1 (semijoin semantics of Φ°).
    """
    db, tree, plan = random_acyclic_db("snowflake4", rng, max_rows=5)
    a = materialize_join(tree)
    cr = compute_counts_reference(plan)
    # check the identity full == rpk * phi_circ — exact division enforced in
    # the reference; and that sum_groups rpk*phi_circ == |A| at every node.
    for i, nd in enumerate(plan.nodes):
        np.testing.assert_array_equal(cr[i]["full"],
                                      cr[i]["rpk"] * cr[i]["phi_circ"])
        assert int(cr[i]["full"].sum()) == a.shape[0]


def test_two_pass_structure():
    """Counts visit each node exactly twice (paper: two passes)."""
    rng = np.random.default_rng(3)
    _, _, plan = random_acyclic_db("chain3", rng)
    # pass structure is encoded in plan.preorder; verify it is a valid
    # preorder of the tree (parents before children).
    seen = set()
    for idx in plan.preorder:
        nd = plan.nodes[idx]
        assert nd.parent == -1 or nd.parent in seen
        seen.add(idx)


def test_counts_default_dtype_exact_above_2pow24():
    """Counts multiply along the tree and cross 2^24 fast; the float32
    default used to round them there (corrupting phi_circ's scaling). The
    float64 default must reproduce the int64 reference exactly."""
    from repro.data.relational import cartesian as cartesian_tree
    from repro.core.join_tree import build_plan

    # |join| = 5001 * 3355 = 16_778_355 > 2^24, and odd — not representable
    # in float32, so the old default provably corrupted it.
    tree = cartesian_tree(5001, 3355, n1=1, n2=1, seed=0)
    plan = build_plan(tree)
    cr = compute_counts_reference(plan)
    root = plan.preorder[0]
    full = int(cr[root]["full"].sum())
    assert full > 2**24 and int(np.float32(full)) != full

    cj = compute_counts(plan)  # default dtype — must be exact
    for i in range(len(plan.nodes)):
        for k in ("rpk", "theta_down", "full", "phi_circ"):
            np.testing.assert_array_equal(np.asarray(cj[i][k]), cr[i][k],
                                          err_msg=f"node{i}:{k}")

    # the regression the default guards against: float32 rounds `full`
    c32 = compute_counts(plan, dtype=jnp.float32)
    assert int(np.asarray(c32[root]["full"]).sum()) != full


@settings(max_examples=25, deadline=None)
@given(topology=st.sampled_from(list(TOPOLOGIES)), seed=st.integers(0, 2**31),
       cartesian=st.booleans())
def test_property_counts_exact(topology, seed, cartesian):
    rng = np.random.default_rng(seed)
    try:
        _, _, plan = random_acyclic_db(topology, rng, cartesian=cartesian)
    except ValueError:  # a relation emptied out in full reduction
        return
    cj = compute_counts(plan, dtype=jnp.float64)
    cr = compute_counts_reference(plan)
    for i in range(len(plan.nodes)):
        for k in ("rpk", "theta_down", "full", "phi_circ"):
            np.testing.assert_allclose(np.asarray(cj[i][k]), cr[i][k],
                                       rtol=1e-12)
