"""Paper §5 / Algorithm 1: batched group-by counts over the join tree."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.counts import compute_counts, compute_counts_reference
from repro.core.materialize import materialize_join

from helpers import TOPOLOGIES, random_acyclic_db


@pytest.mark.parametrize("topology", list(TOPOLOGIES))
def test_counts_match_exact_reference(rng, topology):
    _, _, plan = random_acyclic_db(topology, rng)
    cj = compute_counts(plan, dtype=jnp.float64)
    cr = compute_counts_reference(plan)
    for i in range(len(plan.nodes)):
        for k in ("rpk", "theta_down", "full", "phi_circ", "phi_up"):
            if k in cr[i]:
                np.testing.assert_allclose(np.asarray(cj[i][k]), cr[i][k],
                                           rtol=1e-12, err_msg=f"node{i}:{k}")


def test_full_join_size_equals_materialized(rng):
    """FULL_JOIN_SIZE summed over the root's groups == |A| (join row count)."""
    db, tree, plan = random_acyclic_db("snowflake4", rng)
    a = materialize_join(tree)
    cr = compute_counts_reference(plan)
    root = plan.preorder[0]
    assert int(cr[root]["full"].sum()) == a.shape[0]


def test_phi_circ_semantics_bruteforce(rng):
    """Φ°_i(x̄_i) == size of the join of all relations except S_i at that key.

    Brute-force check on a snowflake: remove one relation's *data* rows but
    keep the key multiplicity 1 (semijoin semantics of Φ°).
    """
    db, tree, plan = random_acyclic_db("snowflake4", rng, max_rows=5)
    a = materialize_join(tree)
    cr = compute_counts_reference(plan)
    # check the identity full == rpk * phi_circ — exact division enforced in
    # the reference; and that sum_groups rpk*phi_circ == |A| at every node.
    for i, nd in enumerate(plan.nodes):
        np.testing.assert_array_equal(cr[i]["full"],
                                      cr[i]["rpk"] * cr[i]["phi_circ"])
        assert int(cr[i]["full"].sum()) == a.shape[0]


def test_two_pass_structure():
    """Counts visit each node exactly twice (paper: two passes)."""
    rng = np.random.default_rng(3)
    _, _, plan = random_acyclic_db("chain3", rng)
    # pass structure is encoded in plan.preorder; verify it is a valid
    # preorder of the tree (parents before children).
    seen = set()
    for idx in plan.preorder:
        nd = plan.nodes[idx]
        assert nd.parent == -1 or nd.parent in seen
        seen.add(idx)


@settings(max_examples=25, deadline=None)
@given(topology=st.sampled_from(list(TOPOLOGIES)), seed=st.integers(0, 2**31),
       cartesian=st.booleans())
def test_property_counts_exact(topology, seed, cartesian):
    rng = np.random.default_rng(seed)
    try:
        _, _, plan = random_acyclic_db(topology, rng, cartesian=cartesian)
    except ValueError:  # a relation emptied out in full reduction
        return
    cj = compute_counts(plan, dtype=jnp.float64)
    cr = compute_counts_reference(plan)
    for i in range(len(plan.nodes)):
        for k in ("rpk", "theta_down", "full", "phi_circ"):
            np.testing.assert_allclose(np.asarray(cj[i][k]), cr[i][k],
                                       rtol=1e-12)
