"""Test helpers: random acyclic databases + R-factor comparison."""

from __future__ import annotations

import numpy as np

from repro.core.join_tree import JoinTree, build_plan
from repro.core.materialize import materialize_join
from repro.core.relation import Database, full_reduce

__all__ = ["random_acyclic_db", "r_close", "TOPOLOGIES"]

# (name, edges, root) — relation names are S1..S4; key attrs named for edges.
TOPOLOGIES = {
    "chain2": ([("S1", "S2")], "S1"),
    "chain3": ([("S1", "S2"), ("S2", "S3")], "S1"),
    "star3": ([("S1", "S2"), ("S1", "S3")], "S1"),
    "snowflake4": ([("S1", "S2"), ("S2", "S3"), ("S2", "S4")], "S1"),
}


def random_acyclic_db(topology: str, rng: np.random.Generator, *,
                      max_rows: int = 9, max_cols: int = 3,
                      max_card: int = 4, cartesian: bool = False,
                      retries: int = 20):
    """Random database + join tree for a named topology.

    Key attribute ``e{i}`` is shared by the two endpoints of edge i. With
    ``cartesian=True`` all key columns are constant (join = Cartesian
    product) — exercises the degenerate grouping path. Redraws (up to
    ``retries``) when full reduction empties a relation out.
    """
    edges, root = TOPOLOGIES[topology]
    rel_attrs: dict[str, list[str]] = {}
    for i, (a, b) in enumerate(edges):
        rel_attrs.setdefault(a, []).append(f"e{i}")
        rel_attrs.setdefault(b, []).append(f"e{i}")
    last_err = None
    for _ in range(retries):
        tables = {}
        for name, attrs in rel_attrs.items():
            m = int(rng.integers(2, max_rows + 1))
            nd = int(rng.integers(1, max_cols + 1))
            keys = {a: (np.zeros(m, np.int64) if cartesian
                        else rng.integers(0, max_card, size=m))
                    for a in attrs}
            tables[name] = (keys, rng.normal(size=(m, nd)),
                            [f"{name.lower()}y{j}" for j in range(nd)])
        db = Database.from_arrays(tables)
        try:
            db = full_reduce(db, edges)
        except ValueError as e:  # some relation emptied out — redraw
            last_err = e
            continue
        tree = JoinTree.from_edges(db, root, edges)
        return db, tree, build_plan(tree)
    raise ValueError(f"no non-empty db after {retries} draws: {last_err}")


def r_close(r_a, r_b, *, rtol=1e-9) -> bool:
    r_a, r_b = np.asarray(r_a), np.asarray(r_b)
    scale = max(np.abs(r_b).max(), 1e-30)
    return np.abs(r_a - r_b).max() / scale < rtol


def materialized(tree: JoinTree) -> np.ndarray:
    return np.asarray(materialize_join(tree))
